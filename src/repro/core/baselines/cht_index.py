"""Standalone CHT index baseline (Crotty 2021): CHT directly over the data.

Indexes the *unique data keys* themselves (no spline), answering with a
delta-bounded window. Like the paper's implementation, it does not support
duplicate keys (the wiki case) — ``build_cht_index`` raises, reproducing the
limitation the paper calls out; PLEX avoids it because spline keys are unique.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..cht import CHT, build_cht


class DuplicateKeysError(ValueError):
    pass


@dataclasses.dataclass
class CHTIndex:
    cht: CHT
    keys: np.ndarray
    name: str = "CHT"

    @property
    def size_bytes(self) -> int:
        return self.cht.size_bytes

    def lookup(self, q: np.ndarray) -> np.ndarray:
        from ..plex import bounded_lower_bound
        q = np.asarray(q, dtype=np.uint64)
        qt = self.cht.lookup(q)
        hi = np.minimum(qt + self.cht.delta, self.keys.size - 1)
        return bounded_lower_bound(self.keys, q, qt, hi, side="left")


def build_cht_index(keys: np.ndarray, r: int = 8, delta: int = 64) -> CHTIndex:
    keys = np.ascontiguousarray(keys, dtype=np.uint64)
    if np.any(keys[1:] == keys[:-1]):
        raise DuplicateKeysError(
            "CHT does not support duplicate keys (paper §4: the wiki dataset)")
    return CHTIndex(cht=build_cht(keys, r, delta), keys=keys)
