"""Two-layer Recursive Model Index (Kraska et al. 2018) baseline.

Linear root (CDF-linear over the key range) dispatching to ``n_models``
second-layer linear models fit by least squares on their key range, with
recorded per-model error bounds (the standard RMI-with-bounds configuration
that CDFShop tunes). Build is fully vectorised via grouped sums.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..spline import _unique_first


@dataclasses.dataclass
class RMI:
    keys: np.ndarray         # full (possibly duplicated) data
    min_key: np.uint64
    scale: float             # n_models / (max - min)
    slopes: np.ndarray       # float64 [M]
    intercepts: np.ndarray   # float64 [M]  (relative to leaf first key)
    first_keys: np.ndarray   # uint64  [M]  centering anchors
    err_lo: np.ndarray       # int32  [M]
    err_hi: np.ndarray       # int32  [M]
    name: str = "RMI"

    @property
    def n_models(self) -> int:
        return self.slopes.size

    @property
    def size_bytes(self) -> int:
        # slope + intercept + anchor + 2 error bounds per leaf model
        return self.n_models * (8 + 8 + 8 + 4 + 4)

    def _leaf(self, q: np.ndarray) -> np.ndarray:
        rel = np.where(q > self.min_key, q - self.min_key,
                       np.uint64(0)).astype(np.float64)
        return np.clip((rel * self.scale).astype(np.int64), 0,
                       self.n_models - 1)

    def predict(self, q: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        q = np.asarray(q, dtype=np.uint64)
        m = self._leaf(q)
        x = (q.astype(np.float64) - self.first_keys[m].astype(np.float64))
        pred = self.slopes[m] * x + self.intercepts[m]
        return pred, self.err_lo[m], self.err_hi[m]

    def lookup(self, q: np.ndarray) -> np.ndarray:
        from ..plex import bounded_lower_bound
        pred, elo, ehi = self.predict(q)
        n = self.keys.size
        lo = np.clip(np.floor(pred).astype(np.int64) - elo, 0, n - 1)
        hi = np.clip(np.ceil(pred).astype(np.int64) + ehi, 0, n - 1)
        return bounded_lower_bound(self.keys, np.asarray(q, np.uint64),
                                   lo, hi, side="left")


def build_rmi(keys: np.ndarray, n_models: int = 1 << 16) -> RMI:
    keys = np.asarray(keys, dtype=np.uint64)
    ukeys, upos = _unique_first(keys)
    lo_k, hi_k = ukeys[0], ukeys[-1]
    span = float(hi_k - lo_k) or 1.0
    scale = n_models / span
    rel = (ukeys - lo_k).astype(np.float64)
    leaf = np.clip((rel * scale).astype(np.int64), 0, n_models - 1)
    # leaf ranges are contiguous (root is monotone)
    starts = np.searchsorted(leaf, np.arange(n_models))
    ends = np.searchsorted(leaf, np.arange(n_models), side="right")
    first_keys = np.where(starts < ukeys.size,
                          ukeys[np.minimum(starts, ukeys.size - 1)],
                          np.uint64(0))
    # grouped least squares on (x = key - first_key, y = rank)
    x = (ukeys - first_keys[leaf]).astype(np.float64)
    y = upos.astype(np.float64)
    cnt = np.zeros(n_models)
    sx = np.zeros(n_models)
    sy = np.zeros(n_models)
    sxx = np.zeros(n_models)
    sxy = np.zeros(n_models)
    np.add.at(cnt, leaf, 1.0)
    np.add.at(sx, leaf, x)
    np.add.at(sy, leaf, y)
    np.add.at(sxx, leaf, x * x)
    np.add.at(sxy, leaf, x * y)
    denom = cnt * sxx - sx * sx
    safe = np.abs(denom) > 1e-12
    slope = np.where(safe, (cnt * sxy - sx * sy) / np.where(safe, denom, 1.0),
                     0.0)
    inter = np.where(cnt > 0, (sy - slope * sx) / np.maximum(cnt, 1.0), 0.0)
    # empty models inherit a constant prediction: the rank of the first key at
    # or after their range (so their error bound stays 0-ish)
    empty = cnt == 0
    if empty.any():
        nxt = np.minimum(starts, ukeys.size - 1)
        inter = np.where(empty, upos[nxt].astype(np.float64), inter)
    # exact per-model error bounds
    pred = slope[leaf] * x + inter[leaf]
    err = y - pred                       # >0: model under-predicts
    elo = np.zeros(n_models)
    ehi = np.zeros(n_models)
    np.maximum.at(ehi, leaf, err)        # need to search upward by ehi
    np.maximum.at(elo, leaf, -err)
    return RMI(keys=keys, min_key=lo_k, scale=scale, slopes=slope,
               intercepts=inter, first_keys=first_keys,
               err_lo=np.ceil(np.maximum(elo, 0)).astype(np.int32),
               err_hi=np.ceil(np.maximum(ehi, 0)).astype(np.int32))
