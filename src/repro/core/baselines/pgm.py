"""PGM-index baseline (Ferragina & Vinciguerra 2020): recursive eps-PLA.

Each level is an eps-bounded piecewise-linear approximation of the level
below; we reuse the greedy corridor builder (an eps-PLA with at most 2x the
optimal segment count — PGM uses the optimal O(N) algorithm, same asymptotics,
noted in DESIGN.md §9). Lookup descends level by level, each step a bounded
binary search within +-eps.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..spline import Spline, build_spline


@dataclasses.dataclass
class PGMIndex:
    keys: np.ndarray
    levels: list[Spline]      # bottom (largest, over the data) first
    eps: int
    name: str = "PGM"

    @property
    def size_bytes(self) -> int:
        return int(sum(lv.size_bytes for lv in self.levels))

    def lookup(self, q: np.ndarray) -> np.ndarray:
        from ..plex import bounded_lower_bound
        q = np.asarray(q, dtype=np.uint64)
        # search window within the current level's key array; the top level is
        # small so its window is the whole level
        lo = np.zeros(q.size, dtype=np.int64)
        hi = np.full(q.size, self.levels[-1].keys.size - 1, dtype=np.int64)
        for i in range(len(self.levels) - 1, -1, -1):
            lv = self.levels[i]
            seg = bounded_lower_bound(lv.keys, q, lo, hi, side="right")
            seg = np.clip(seg, 0, lv.keys.size - 2)
            pred = lv.predict_in_segment(q, seg)
            below = self.keys.size if i == 0 else self.levels[i - 1].keys.size
            lo = np.clip(np.floor(pred).astype(np.int64) - self.eps,
                         0, below - 1)
            hi = np.clip(np.ceil(pred).astype(np.int64) + self.eps,
                         0, below - 1)
        return bounded_lower_bound(self.keys, q, lo, hi, side="left")


def build_pgm(keys: np.ndarray, eps: int, *, top_threshold: int = 64
              ) -> PGMIndex:
    keys = np.asarray(keys, dtype=np.uint64)
    levels = [build_spline(keys, eps)]
    while levels[-1].keys.size > top_threshold:
        levels.append(build_spline(levels[-1].keys, eps))
    return PGMIndex(keys=keys, levels=levels, eps=eps)
