"""Baseline index structures the paper evaluates PLEX against (Figs. 2-3).

All share the lookup contract of `repro.core.plex.PLEX.lookup`: vectorised
first-occurrence index of present keys (lower bound for absent ones).
ART is omitted — pointer-chasing adaptive nodes are CPU-specific and do not
transfer to the batched/TPU setting (DESIGN.md §9); BTree covers the classical
comparison point.
"""
from .bsearch import BinarySearch
from .btree import BTree
from .cht_index import CHTIndex
from .pgm import PGMIndex
from .radixspline import RadixSpline
from .rmi import RMI

__all__ = ["BinarySearch", "BTree", "CHTIndex", "PGMIndex", "RadixSpline",
           "RMI"]
