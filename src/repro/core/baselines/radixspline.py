"""RadixSpline baseline (Kipf et al. 2020): eps-spline + fixed-r radix table.

Identical to PLEX except the radix layer is a flat table whose ``r`` is a
*hyperparameter* (no auto-tuning, no CHT option) — this is what exposes RS to
the outlier problem the paper demonstrates on ``face``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..radix_table import RadixTable, build_radix_table
from ..spline import Spline, build_spline


@dataclasses.dataclass
class RadixSpline:
    spline: Spline
    table: RadixTable
    keys: np.ndarray
    eps: int
    name: str = "RadixSpline"

    @property
    def size_bytes(self) -> int:
        return self.spline.size_bytes + self.table.size_bytes

    def predict(self, q: np.ndarray) -> np.ndarray:
        from ..plex import bounded_lower_bound
        q = np.asarray(q, dtype=np.uint64)
        lo, hi = self.table.lookup(q)
        seg = bounded_lower_bound(self.spline.keys, q, lo, hi, side="right")
        seg = np.clip(seg, 0, self.spline.keys.size - 2)
        return self.spline.predict_in_segment(q, seg)

    def lookup(self, q: np.ndarray) -> np.ndarray:
        from ..plex import bounded_lower_bound
        q = np.asarray(q, dtype=np.uint64)
        pred = self.predict(q)
        n = self.keys.size
        lo = np.clip(np.floor(pred).astype(np.int64) - self.eps, 0, n - 1)
        hi = np.clip(np.ceil(pred).astype(np.int64) + self.eps, 0, n - 1)
        return bounded_lower_bound(self.keys, q, lo, hi, side="left")


def build_radixspline(keys: np.ndarray, eps: int, r: int = 18) -> RadixSpline:
    keys = np.ascontiguousarray(keys, dtype=np.uint64)
    spline = build_spline(keys, eps)
    table = build_radix_table(spline.keys, r)
    return RadixSpline(spline=spline, table=table, keys=keys, eps=eps)
