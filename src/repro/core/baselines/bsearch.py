"""Binary search over the raw key array — the zero-size baseline."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BinarySearch:
    keys: np.ndarray
    name: str = "BinarySearch"

    @property
    def size_bytes(self) -> int:
        return 0

    def lookup(self, q: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.keys, np.asarray(q, dtype=np.uint64),
                               side="left")


def build_binary_search(keys: np.ndarray) -> BinarySearch:
    return BinarySearch(keys=np.asarray(keys, dtype=np.uint64))
