"""Distribution-faithful synthetic stand-ins for the SOSD datasets.

The paper evaluates on four 200M-key 64-bit SOSD datasets which cannot be
downloaded in this offline container (DESIGN.md §9). Each generator below
reproduces the *structural property* that drives index behaviour:

* ``amzn`` — book-popularity data: smooth heavy-tailed CDF (lognormal
  mixture). Easy for splines, moderate for radix layers.
* ``face`` — Facebook user ids: a dense low region plus a sparse band of
  extreme outliers in the high bits. This is the documented RadixSpline
  failure mode (most radix-table prefixes are wasted on the outlier span) and
  the dataset where PLEX's tuner must pick CHT.
* ``osm`` — composite OpenStreetMap cell ids: hierarchically clustered,
  multi-scale structure that is "hard to learn" for model-based indexes but
  friendly to radix approaches.
* ``wiki`` — Wikipedia edit timestamps: near-arithmetic sequence *with
  duplicate keys* (the case plain CHT rejects and PLEX handles, paper §4).

Sizes are configurable; defaults keep CI fast. Generators are deterministic
given (name, n, seed).
"""
from __future__ import annotations

import numpy as np

DATASETS = ("amzn", "face", "osm", "wiki")


def _amzn(rng: np.random.Generator, n: int) -> np.ndarray:
    parts = []
    for mu, sigma, w in ((18.0, 1.2, 0.5), (21.0, 0.8, 0.3), (15.0, 2.0, 0.2)):
        m = int(n * w)
        parts.append(np.exp(rng.normal(mu, sigma, m)))
    x = np.concatenate(parts)[:n]
    while x.size < n:
        x = np.concatenate([x, np.exp(rng.normal(18.0, 1.2, n - x.size))])
    x = (x / x.max() * float(2**62)).astype(np.uint64)
    return np.sort(x)


def _face(rng: np.random.Generator, n: int) -> np.ndarray:
    # dense low region must itself be hard enough that the spline has many
    # points (clustered ids), so the outliers genuinely waste radix prefixes
    n_out = max(n // 1000, 4)                    # 0.1% extreme outliers
    n_dense = n - n_out
    n_cl = max(n_dense // 500, 8)
    centers = rng.integers(1 << 20, 1 << 40, n_cl, dtype=np.uint64)
    picks = centers[rng.integers(0, n_cl, n_dense)]
    jitter = rng.integers(0, 1 << 14, n_dense, dtype=np.uint64)
    dense = picks + jitter
    outl = rng.integers(1 << 58, 1 << 63, n_out, dtype=np.uint64)
    return np.sort(np.concatenate([dense, outl]))


def _osm(rng: np.random.Generator, n: int) -> np.ndarray:
    # hierarchical clusters: coarse cells -> fine cells -> points
    n_coarse = max(n // 10000, 8)
    coarse = rng.integers(0, 1 << 62, n_coarse, dtype=np.uint64)
    picks = coarse[rng.integers(0, n_coarse, n)]
    fine = rng.integers(0, 1 << 36, n, dtype=np.uint64)
    jitter = rng.integers(0, 1 << 16, n, dtype=np.uint64)
    return np.sort(picks + fine + jitter)


def _wiki(rng: np.random.Generator, n: int) -> np.ndarray:
    # edit timestamps: bursty arrivals, ~8% duplicate keys
    gaps = rng.geometric(0.35, n).astype(np.uint64) - np.uint64(1)
    base = np.uint64(1_600_000_000)
    return base + np.cumsum(gaps).astype(np.uint64)


def generate(name: str, n: int = 200_000, seed: int = 0) -> np.ndarray:
    """Sorted uint64 keys for dataset ``name`` (see module docstring).
    Seeding uses a *stable* hash — Python's ``hash()`` is salted per
    process, which would make datasets irreproducible across runs."""
    import zlib
    rng = np.random.default_rng(zlib.crc32(f"{name}:{seed}".encode()))
    if name == "amzn":
        return _amzn(rng, n)
    if name == "face":
        return _face(rng, n)
    if name == "osm":
        return _osm(rng, n)
    if name == "wiki":
        return _wiki(rng, n)
    raise KeyError(f"unknown dataset {name!r}; options: {DATASETS}")
