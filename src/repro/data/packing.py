"""PLEX-indexed sequence packing (first-class integration #1, DESIGN.md §4).

Packing a token stream into fixed-length training sequences needs
``global token position -> (document id, offset)`` — a predecessor query
over the cumulative-token-count array. At corpus scale (10^8+ documents)
that array is exactly the sorted-u64-key workload PLEX indexes: we build a
PLEX over the document boundaries once (O(N), single pass) and answer every
pack step's batched queries through it. Correctness is the paper's eps
guarantee + bounded final search — verified against np.searchsorted in tests.

The pipeline is *stateless-resumable*: batch(step, host) is a pure function
of (seed, step, host), so restart/elastic-rescale just replays from the
checkpointed step (no iterator state to snapshot), and every host can verify
any other host's shard (straggler auditing).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core import PLEX, build_plex


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic synthetic tokenized corpus: doc lengths + token stream."""
    n_docs: int
    vocab: int
    seed: int = 0
    mean_len: int = 512

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        lens = rng.geometric(1.0 / self.mean_len, self.n_docs
                             ).astype(np.uint64) + np.uint64(16)
        self.doc_lens = lens
        self.boundaries = np.concatenate(
            [[np.uint64(0)], np.cumsum(lens)]).astype(np.uint64)
        self.total_tokens = int(self.boundaries[-1])

    def tokens(self, doc: int, start: int, n: int) -> np.ndarray:
        """Tokens [start, start+n) of a document (hash-based, O(n))."""
        rng = np.random.default_rng((self.seed << 20) ^ doc)
        # deterministic per-doc stream; skip-ahead via generator state is
        # avoided by hashing (doc, block) chunks
        out = np.empty(n, dtype=np.int32)
        blk = 4096
        i = 0
        while i < n:
            b = (start + i) // blk
            off = (start + i) % blk
            brng = np.random.default_rng((self.seed << 40) ^ (doc << 16) ^ b)
            # zipf-ish skew: a learnable unigram distribution (uniform would
            # pin CE at ln(V) and hide training-progress bugs)
            u = brng.random(blk)
            chunk = np.minimum((u ** 3 * self.vocab).astype(np.int32),
                               self.vocab - 1)
            take = min(blk - off, n - i)
            out[i:i + take] = chunk[off:off + take]
            i += take
        return out


class PackedIndex:
    """PLEX over document boundaries; batched position->document lookups."""

    def __init__(self, corpus: SyntheticCorpus, eps: int = 64):
        self.corpus = corpus
        self.plex: PLEX = build_plex(corpus.boundaries, eps=eps)

    def locate(self, positions: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Global token positions -> (doc ids, in-doc offsets). Exact."""
        positions = np.asarray(positions, dtype=np.uint64)
        # lower_bound over boundaries; boundary keys are unique so the
        # predecessor document is lb-1 except at exact boundary hits
        lb = self.plex.lookup(positions)
        exact = (self.corpus.boundaries[np.minimum(
            lb, self.corpus.n_docs)] == positions)
        doc = np.where(exact, lb, lb - 1).astype(np.int64)
        doc = np.clip(doc, 0, self.corpus.n_docs - 1)
        off = positions - self.corpus.boundaries[doc]
        return doc, off.astype(np.int64)


@dataclasses.dataclass
class PackedPipeline:
    """Deterministic packed-batch source feeding train_step."""
    corpus: SyntheticCorpus
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    eps: int = 64

    def __post_init__(self):
        self.index = PackedIndex(self.corpus, self.eps)
        self.tokens_per_step = self.seq_len * self.global_batch

    def batch(self, step: int, host: int = 0) -> dict:
        """Batch for (step, host): tokens + next-token labels [B/host, S]."""
        assert self.global_batch % self.n_hosts == 0
        b = self.global_batch // self.n_hosts
        start = (np.uint64(step) * np.uint64(self.tokens_per_step)
                 + np.uint64(host * b * self.seq_len))
        start = start % np.uint64(max(self.corpus.total_tokens
                                      - self.tokens_per_step - 1, 1))
        pos = start + np.arange(b, dtype=np.uint64) * np.uint64(self.seq_len)
        docs, offs = self.index.locate(pos)
        toks = np.empty((b, self.seq_len + 1), np.int32)
        for i, (d, o) in enumerate(zip(docs, offs)):
            # fill crossing document boundaries as a contiguous stream
            need = self.seq_len + 1
            row = []
            dd, oo = int(d), int(o)
            while need > 0:
                avail = int(self.corpus.doc_lens[dd]) - oo
                take = min(avail, need)
                row.append(self.corpus.tokens(dd, oo, take))
                need -= take
                dd = (dd + 1) % self.corpus.n_docs
                oo = 0
            toks[i] = np.concatenate(row)[:self.seq_len + 1]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
