from .sosd import DATASETS, generate

__all__ = ["DATASETS", "generate"]
