"""Trace-time flags.

``unrolled_scans()``: within this context every lax.scan in the model stack
unrolls. Used by the dry-run's HLO-cost probes — XLA's HloCostAnalysis counts
a while-loop body ONCE regardless of trip count, so FLOP/byte/collective
totals from scanned programs under-count by the trip count; the probes
compile small-depth unrolled variants and extrapolate (launch/dryrun.py).
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def scan_unroll() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled_scans(on: bool = True):
    prev = scan_unroll()
    _state.unroll = on
    try:
        yield
    finally:
        _state.unroll = prev
